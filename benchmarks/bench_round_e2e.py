"""Whole-round fusion: wall-clock per federated round, both engines.

Measures the three execution models of the round loop (Algorithm 1):

  eager  — the stage-by-stage reference: separately dispatched InitState,
           jitted local training, eager 𝒜 + 𝒮 between jit boundaries
           (FedEngine ``fused_round=False``; ShardedFederation
           ``fused_round=False`` = jit-𝒯𝒜 + host-𝒮).
  fused  — the whole round as ONE jitted, buffer-donated program.
  scan   — K rounds as ONE ``lax.scan`` dispatch (``run_rounds``).

Reports seconds/round and rounds/sec across client counts for the reference
FedEngine (multi-block toy problem, two workload regimes) and the SPMD
ShardedFederation (smoke transformer on a host mesh). The acceptance numbers
— fused vs eager at C=8 and scan vs per-round fused dispatch at K=10 — land
in the JSON.

Regimes: fusing the round wins on two distinct axes, measured separately.
``compute`` (wider blocks, more local steps) shows the eager→fused win: the
eager round pays O(clients·leaves) host dispatches that fusion collapses
into one program. ``dispatch`` (small blocks, T=1 — the ROADMAP's
many-small-federated-scenarios serving regime) additionally shows the
fused→scan win: once the round is a single program, per-round dispatch +
host metric sync is the remaining overhead, and the K-round scan amortizes
it to one dispatch per sweep.

Cohort sweep (``bench_cohort``): the factored-client memory model's scaling
axis. Sweeps C ∈ {8, 64, 512} through the chunk-streamed fused round on a
wide-block problem, reporting wall-clock alongside **peak client-buffer
bytes** (the persistent per-client round state the factored representation
shrinks from O(C·m·n) to O(C·r(m+n))), against the retired dense-stack model
at C=8 — and, at each C, the **lift-free** delta-context round (the default)
against the transient-lift oracle (``lift_free=False``: materialize
``base_scale·W + lift(R_i)`` per leaf per step, dense AD, re-project). The
C=512 lift-free round is the headline number; a per-stage breakdown
(InitState+local 𝒯 vs 𝒜 vs 𝒮, separately jitted) localizes where round time
goes. Acceptance: the C=512 round stays within the recorded budget
(regression guard, not just a recording), lift-free is no slower than
transient-lift at the compute-bound cohort shape, buffers stay within 4× the
old C=8 dense configuration, and factored-vs-dense parity ≤ 1e-4 at C=8.

Batched-bucket 𝒮 + pipelined-scan gates (``scripts/ci.sh --sync-smoke``):
the stage breakdown's 𝒮 number must stay within ``SYNC_STAGE_BUDGET_S`` at
the C=64 breakdown cohort (the shape-bucketed vmapped sync replacing the
per-leaf loop), and the pipelined K-round scan (``pipeline_sync=True``, the
default — round k's 𝒮 overlapped with round k+1's local phase) must be no
slower than the sequential oracle at every cohort size, up to
``PIPE_NOISE_TOL``. Stage timings fence their inputs with
``block_until_ready`` before the clock read (async dispatch otherwise
charges upstream compute to the wrong stage).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.fed import FedConfig, FedEngine
from .common import dump_json, emit

SCAN_ROUNDS = 10        # K for the scan-over-rounds acceptance number

ENGINE_REGIMES = {
    # regime -> (n_blocks, width, local_steps, batch)
    "compute": (4, 48, 2, 4),
    "dispatch": (2, 16, 1, 2),
}


def _engine_problem(n_blocks, width):
    """A multi-block toy model (several same-shape target matrices + biases)
    so the eager round pays realistic per-leaf dispatch costs."""
    key = jax.random.PRNGKey(0)
    params = {}
    for i in range(n_blocks):
        params[f"w{i}"] = 0.2 * jax.random.normal(
            jax.random.fold_in(key, i), (width, width))
        params[f"b{i}"] = jnp.zeros((width,))
    params["head"] = 0.2 * jax.random.normal(
        jax.random.fold_in(key, 99), (width, 8))

    def loss(p, batch):
        x, y = batch
        h = x
        for i in range(n_blocks):
            h = jnp.tanh(h @ p[f"w{i}"] + p[f"b{i}"])
        return jnp.mean((h @ p["head"] - y) ** 2)

    def batches(seed, k_clients, t_steps, b, k_rounds=None):
        kk = jax.random.PRNGKey(seed)
        lead = ((k_clients, t_steps) if k_rounds is None
                else (k_rounds, k_clients, t_steps))
        x = jax.random.normal(kk, lead + (b, width))
        y = jax.random.normal(jax.random.fold_in(kk, 1), lead + (b, 8))
        return (x, y)

    return params, loss, batches


def _best_of(fn, reps=3):
    return min(fn() for _ in range(reps))


def _time_rounds(run_one, n_rounds):
    t0 = time.perf_counter()
    for r in range(n_rounds):
        run_one(r)
    return (time.perf_counter() - t0) / n_rounds


def bench_engine(clients, regime="dispatch", rounds_timed=10, rank=4,
                 reps=5):
    n_blocks, width, local_steps, b = ENGINE_REGIMES[regime]
    params, loss, batches = _engine_problem(n_blocks, width)
    rows = []
    for c in clients:
        per = {"engine": "FedEngine", "regime": regime, "clients": c,
               "local_steps": local_steps, "width": width,
               "n_blocks": n_blocks}
        for mode in ("eager", "fused"):
            # eager = the strongest stage-by-stage baseline (PR-1 state:
            # factored 𝒮, bucketed GaLore) so the speedup isolates round
            # fusion, not the factored-vs-dense sync win.
            eng = FedEngine(FedConfig(method="fedgalore", rank=rank, lr=1e-2,
                                      local_steps=local_steps,
                                      fused_round=(mode == "fused")),
                            loss, params)
            for r in range(2):          # compile both traces + adaptive r0
                eng.run_round(batches(r, c, local_steps, b))
            bs = [batches(10 + r, c, local_steps, b) for r in range(3)]
            jax.block_until_ready(bs)
            n = rounds_timed if mode == "fused" else max(rounds_timed // 3, 2)

            def loop(eng=eng, bs=bs, n=n):
                t0 = time.perf_counter()
                for r in range(n):
                    eng.run_round(bs[r % 3])
                return (time.perf_counter() - t0) / n
            per[f"{mode}_s"] = _best_of(loop, reps if mode == "fused" else 1)
        # scan-over-rounds: K rounds in one dispatch
        eng = FedEngine(FedConfig(method="fedgalore", rank=rank, lr=1e-2,
                                  local_steps=local_steps), loss, params)
        rb = batches(0, c, local_steps, b, k_rounds=SCAN_ROUNDS)
        eng.run_rounds(rb)              # compile

        def scan_loop(eng=eng, rb=rb):
            t0 = time.perf_counter()
            eng.run_rounds(rb)
            return (time.perf_counter() - t0) / SCAN_ROUNDS
        per["scan_s"] = _best_of(scan_loop, reps)
        per["scan_rounds"] = SCAN_ROUNDS
        per["fused_speedup"] = per["eager_s"] / per["fused_s"]
        per["scan_speedup_vs_fused"] = per["fused_s"] / per["scan_s"]
        rows.append(per)
        tag = f"round_e2e/engine_{regime}_c{c}"
        emit(f"{tag}_eager", per["eager_s"] * 1e6,
             f"rounds_per_s={1.0 / per['eager_s']:.1f}")
        emit(f"{tag}_fused", per["fused_s"] * 1e6,
             f"speedup={per['fused_speedup']:.2f}x")
        emit(f"{tag}_scan", per["scan_s"] * 1e6,
             f"vs_fused={per['scan_speedup_vs_fused']:.2f}x")
    return rows


COHORT_CLIENTS = (8, 64, 512)
COHORT_WIDTH = 512      # wide blocks: the regime where O(m·n) vs O(r(m+n))
COHORT_RANK = 4         # per-client state is the whole story
COHORT_CHUNK = 32       # B: dense transient working set bounded by 32 clients
# Regression guard for the headline C=512 round (seconds on this CPU): the
# PR 4 transient-lift baseline measured 6.85 s — the lift-free round must
# never regress past it. Update deliberately when the workload changes.
COHORT_CMAX_ROUND_S_BUDGET = 6.85
# 𝒮-stage budget at the C=64 breakdown point: the batched-bucket sync must
# hold the per-round 𝒮 under 10 ms (pre-bucketing per-leaf loop: ~26 ms).
SYNC_STAGE_BUDGET_S = 0.010
PIPE_ROUNDS = 4         # K floor for the pipelined-vs-sequential comparison
# Small cohorts run more rounds per timed scan (K = max(PIPE_ROUNDS,
# PIPE_SCAN_STEPS // C)) so every measurement covers ≳100 ms of work — a
# 4-round C=8 scan is ~13 ms and single-digit-percent scheduler noise on
# it dwarfs the effect being gated.
PIPE_SCAN_STEPS = 512
PIPE_REPS = 5           # interleaved best-of reps per schedule
# Pipelined ≥ sequential up to scheduler noise: per-round scan times on this
# shared CPU jitter a few percent between best-of runs even for the *same*
# program, so the gate allows 3% before calling a regression.
PIPE_NOISE_TOL = 1.03


def _tree_maxerr(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _stage_breakdown(eng, c, batches, w=None, reps=12):
    """Per-stage wall-clock of the factored round: separately jitted
    InitState+local 𝒯, aggregation 𝒜, and state-sync 𝒮 (their sum exceeds
    the fused round, which overlaps dispatch — the split localizes where
    time goes, it does not replace the fused number).

    Every rep fences the stage *inputs* with ``block_until_ready`` before
    reading the clock: JAX dispatch is async, so without the fence a stage
    timed right after producing its inputs silently absorbs the tail of the
    upstream stage's compute (the old numbers charged part of 𝒯 to 𝒜/𝒮).
    Best-of-``reps`` because the r×r stage times are single-digit ms — small
    enough for scheduler noise to dominate a mean on a contended host."""
    w = jnp.full((c,), 1.0 / c) if w is None else w
    ridx = jnp.asarray(1, jnp.int32)      # steady state: past adaptive r0

    @jax.jit
    def local_stage(global_tr, frozen, bat):
        st0 = eng._init_state0(ridx, None, global_tr)
        opt0 = eng._stack_opt_state(st0, c)
        deltas0 = eng._stack_deltas0(st0, c)
        fn = (eng._local_train_liftfree_one if eng._lift_free
              else eng._local_train_factored_one)
        return jax.vmap(fn, in_axes=(0, eng._opt_axes, 0, None, None),
                        out_axes=(0, eng._opt_axes, 0, 0))(
            deltas0, opt0, bat, frozen, global_tr)

    @jax.jit
    def agg_stage(global_tr, out_d, out_opt, scales):
        return eng._aggregate_factored(global_tr, out_d, out_opt, scales,
                                       w, ridx)

    @jax.jit
    def sync_stage(out_opt):
        return eng._sync_states_pure(out_opt, w, ridx)

    def timed(fn, *args):
        jax.block_until_ready(fn(*args))                  # compile
        best = float("inf")
        for _ in range(reps):
            jax.block_until_ready(args)     # fence inputs: async dispatch
            t0 = time.perf_counter()        # must not leak upstream compute
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    local_s = timed(local_stage, eng.global_trainable, eng.frozen, batches)
    out_d, out_opt, _, scales = local_stage(eng.global_trainable, eng.frozen,
                                            batches)
    agg_s = timed(agg_stage, eng.global_trainable, out_d, out_opt, scales)
    sync_s = timed(sync_stage, out_opt)
    return {"local_s": local_s, "agg_s": agg_s, "sync_s": sync_s}


def bench_cohort(clients=COHORT_CLIENTS, rounds_timed=2):
    """Cohort-size sweep of the factored chunk-streamed round (fedgalore,
    T=1): the lift-free delta-context round (default) vs the transient-lift
    oracle at every C, vs the retired dense-stack client model at C=8 —
    wall-clock + peak client-buffer bytes + parity + per-stage breakdown."""
    n_blocks, width, local_steps, b = 2, COHORT_WIDTH, 1, 2
    params, loss, batches = _engine_problem(n_blocks, width)

    def make(factored, chunk=None, lift_free=True):
        # Cohort size comes from the batch leading dim at run_round time.
        return FedEngine(FedConfig(method="fedgalore", rank=COHORT_RANK,
                                   lr=1e-2, local_steps=local_steps,
                                   factored_clients=factored,
                                   client_chunk=chunk, lift_free=lift_free),
                        loss, params)

    def run(eng, c, n_rounds, offset=0):
        t0 = time.perf_counter()
        for r in range(n_rounds):
            eng.run_round(batches(offset + r, c, local_steps, b))
        return (time.perf_counter() - t0) / n_rounds

    rows = []
    # The old configuration: dense per-client weight stacks, C=8, one chunk.
    dense8 = make(factored=False)
    run(dense8, 8, 2)                                  # compile + round 1
    dense8_s = run(dense8, 8, rounds_timed, offset=10)
    dense8_bytes = dense8.client_buffer_bytes()
    rows.append({"engine": "FedEngine", "sweep": "cohort", "clients": 8,
                 "client_model": "dense", "chunk": None,
                 "round_s": dense8_s, "client_buffer_bytes": dense8_bytes})
    emit("round_e2e/cohort_c8_dense", dense8_s * 1e6,
         f"buffer_bytes={dense8_bytes}")

    # Parity at C=8 (identical batches, 2 rounds): lift-free vs the
    # transient-lift oracle, and lift-free vs the dense-stack oracle.
    lf8, tr8, dense8b = make(True), make(True, lift_free=False), make(False)
    for r in range(2):
        for e in (lf8, tr8, dense8b):
            e.run_round(batches(r, 8, local_steps, b))
    parity_lf_tr = max(_tree_maxerr(lf8.global_trainable, tr8.global_trainable),
                       _tree_maxerr(lf8.synced_v, tr8.synced_v))
    parity = max(_tree_maxerr(lf8.global_trainable, dense8b.global_trainable),
                 _tree_maxerr(lf8.synced_v, dense8b.synced_v))

    liftfree_s, transient_s = {}, {}
    for c in clients:
        chunk = min(COHORT_CHUNK, c)
        for lift_free in (True, False):
            eng = make(factored=True, chunk=chunk, lift_free=lift_free)
            run(eng, c, 2)
            sec = run(eng, c, rounds_timed, offset=10)
            (liftfree_s if lift_free else transient_s)[c] = sec
            nbytes = eng.client_buffer_bytes()
            model = "liftfree" if lift_free else "transient_lift"
            rows.append({"engine": "FedEngine", "sweep": "cohort",
                         "clients": c, "client_model": model, "chunk": chunk,
                         "round_s": sec, "client_buffer_bytes": nbytes,
                         "buffer_vs_c8_dense": nbytes / dense8_bytes})
            emit(f"round_e2e/cohort_c{c}_{model}", sec * 1e6,
                 f"buffer_bytes={nbytes} "
                 f"vs_c8_dense={nbytes / dense8_bytes:.2f}x")

    # Stage breakdown at an unchunked mid-size cohort (the split isolates
    # per-stage compute; unchunked keeps one vmapped local program, and C=64
    # bounds the transient path's per-client dense working set).
    cmax = max(clients)
    bc = min(64, cmax)
    sync_bc_s = None
    for lift_free in (True, False):
        eng = make(factored=True, lift_free=lift_free)
        eng.run_round(batches(0, bc, local_steps, b))     # warm buffers
        stages = _stage_breakdown(eng, bc,
                                  batches(1, bc, local_steps, b))
        model = "liftfree" if lift_free else "transient_lift"
        if lift_free:
            sync_bc_s = stages["sync_s"]
        rows.append({"engine": "FedEngine", "sweep": "stage_breakdown",
                     "clients": bc, "client_model": model, **stages})
        emit(f"round_e2e/stages_c{bc}_{model}",
             stages["local_s"] * 1e6,
             f"agg={stages['agg_s'] * 1e6:.0f}us "
             f"sync={stages['sync_s'] * 1e6:.0f}us")

    # Pipelined vs sequential K-round scan at every cohort size: the
    # one-round-deep schedule must never cost throughput (it is the same
    # round math re-associated; see core.fed). Both engines are compiled
    # first and the timed reps interleave pipelined/sequential, so slow
    # machine drift (the shared host's scheduler and cache state wander on
    # the seconds scale) hits both sides equally instead of biasing
    # whichever ran second; best-of over whole scans.
    pipe_s, seq_s, pipe_k = {}, {}, {}
    for c in clients:
        chunk = min(COHORT_CHUNK, c)
        k_rounds = max(PIPE_ROUNDS, PIPE_SCAN_STEPS // c)
        pipe_k[c] = k_rounds
        rb = batches(0, c, local_steps, b, k_rounds=k_rounds)
        engines = {}
        for pipelined in (True, False):
            eng = FedEngine(FedConfig(method="fedgalore", rank=COHORT_RANK,
                                      lr=1e-2, local_steps=local_steps,
                                      factored_clients=True,
                                      client_chunk=chunk,
                                      pipeline_sync=pipelined),
                            loss, params)
            eng.run_rounds(rb)                            # compile
            engines[pipelined] = eng

        def scan_once(eng, rb=rb, k_rounds=k_rounds):
            t0 = time.perf_counter()
            eng.run_rounds(rb)
            return (time.perf_counter() - t0) / k_rounds

        best = {True: float("inf"), False: float("inf")}
        for _ in range(PIPE_REPS):
            for pipelined in (True, False):
                best[pipelined] = min(best[pipelined],
                                      scan_once(engines[pipelined]))
        pipe_s[c], seq_s[c] = best[True], best[False]
        for pipelined in (True, False):
            rows.append({"engine": "FedEngine", "sweep": "pipeline",
                         "clients": c, "chunk": chunk, "rounds": k_rounds,
                         "pipelined": pipelined, "round_s": best[pipelined]})
        emit(f"round_e2e/pipeline_c{c}", pipe_s[c] * 1e6,
             f"sequential={seq_s[c] * 1e6:.0f}us "
             f"speedup={seq_s[c] / pipe_s[c]:.2f}x")

    cmax_bytes = next(r["client_buffer_bytes"] for r in rows
                      if r.get("clients") == cmax
                      and r.get("client_model") == "liftfree")
    return rows, {
        "cohort_cmax": cmax,
        "cohort_cmax_round_s": liftfree_s[cmax],
        "cohort_cmax_round_s_transient": transient_s[cmax],
        "cohort_cmax_round_s_budget": COHORT_CMAX_ROUND_S_BUDGET,
        "cohort_cmax_within_budget":
            liftfree_s[cmax] <= COHORT_CMAX_ROUND_S_BUDGET,
        "liftfree_speedup_cmax": transient_s[cmax] / liftfree_s[cmax],
        "liftfree_speedup_by_clients": {
            str(c): transient_s[c] / liftfree_s[c] for c in clients},
        "cohort_cmax_buffer_bytes": cmax_bytes,
        "c8_dense_buffer_bytes": dense8_bytes,
        "cohort_buffer_ratio_cmax_vs_c8_dense": cmax_bytes / dense8_bytes,
        "factored_parity_c8": parity,
        "liftfree_parity_c8": parity_lf_tr,
        # batched-bucket 𝒮 + pipelined-scan gates (see module constants)
        "sync_stage_clients": bc,
        "sync_stage_s": sync_bc_s,
        "sync_stage_budget_s": SYNC_STAGE_BUDGET_S,
        "sync_stage_within_budget": sync_bc_s <= SYNC_STAGE_BUDGET_S,
        "pipeline_rounds_by_clients": {str(c): pipe_k[c] for c in clients},
        "pipeline_noise_tol": PIPE_NOISE_TOL,
        "pipeline_round_s_by_clients": {str(c): pipe_s[c] for c in clients},
        "sequential_round_s_by_clients": {str(c): seq_s[c] for c in clients},
        "pipeline_speedup_by_clients": {
            str(c): seq_s[c] / pipe_s[c] for c in clients},
        "pipelined_ge_sequential": all(
            pipe_s[c] <= seq_s[c] * PIPE_NOISE_TOL for c in clients),
    }


def bench_runtime(clients, local_steps=2, rounds_timed=3):
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=local_steps,
                     refresh_mode="random")

    def batches(seed, c, k_rounds=None, b=2, seq=8):
        kk = jax.random.PRNGKey(seed)
        lead = ((c, local_steps, b, seq) if k_rounds is None
                else (k_rounds, c, local_steps, b, seq))
        toks = jax.random.randint(kk, lead, 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    rows = []
    for c in clients:
        per = {"engine": "ShardedFederation", "clients": c,
               "local_steps": local_steps}
        for mode in ("eager", "fused"):
            fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                    fused_round=(mode == "fused"))
            # two warmup rounds: round 2's inputs carry round 1's output
            # shardings, so the steady-state executable exists before timing
            for r in range(2):
                fed.run_round(batches(r, c))
            bs = [batches(10 + r, c) for r in range(2)]
            per[f"{mode}_s"] = _best_of(
                lambda: _time_rounds(lambda r: fed.run_round(bs[r % 2]),
                                     rounds_timed), 2)
        fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
        rb = batches(0, c, k_rounds=SCAN_ROUNDS)
        for _ in range(2):                          # compile + steady state
            fed.run_rounds(rb)

        def scan_loop(fed=fed, rb=rb):
            t0 = time.perf_counter()
            fed.run_rounds(rb)
            return (time.perf_counter() - t0) / SCAN_ROUNDS
        per["scan_s"] = _best_of(scan_loop, 2)
        per["scan_rounds"] = SCAN_ROUNDS
        per["fused_speedup"] = per["eager_s"] / per["fused_s"]
        per["scan_speedup_vs_fused"] = per["fused_s"] / per["scan_s"]
        rows.append(per)
        emit(f"round_e2e/runtime_c{c}_eager", per["eager_s"] * 1e6,
             f"rounds_per_s={1.0 / per['eager_s']:.1f}")
        emit(f"round_e2e/runtime_c{c}_fused", per["fused_s"] * 1e6,
             f"speedup={per['fused_speedup']:.2f}x")
        emit(f"round_e2e/runtime_c{c}_scan", per["scan_s"] * 1e6,
             f"vs_fused={per['scan_speedup_vs_fused']:.2f}x")
    return rows


def main(clients=(4, 8, 16), out_path="bench_round_e2e.json",
         include_runtime=True, smoke=False):
    if smoke:
        clients = tuple(c for c in clients if c <= 8) or (4, 8)
    rows = bench_engine(clients, regime="compute")
    rows += bench_engine(clients, regime="dispatch")
    cohort_rows, cohort_acc = bench_cohort()
    rows += cohort_rows
    if include_runtime:
        rows += bench_runtime(clients if not smoke else (4,))

    def row(regime, c):
        return next(r for r in rows if r["engine"] == "FedEngine"
                    and r.get("regime") == regime and r["clients"] == c)

    c8c, c8d = row("compute", 8), row("dispatch", 8)
    result = {
        "rows": rows,
        # fused-vs-eager from the compute regime (the O(clients·leaves)
        # eager dispatches it collapses); scan-vs-per-round-dispatch from
        # the dispatch-bound serving regime it amortizes.
        "acceptance": {
            "fused_speedup_c8": c8c["fused_speedup"],
            "scan_speedup_vs_fused_k10_c8": c8d["scan_speedup_vs_fused"],
            "scan_speedup_vs_fused_k10_by_clients": {
                str(c): row("dispatch", c)["scan_speedup_vs_fused"]
                for c in clients},
            "scan_speedup_vs_eager_k10_c8": c8d["eager_s"] / c8d["scan_s"],
            **cohort_acc,
        },
    }
    dump_json(out_path, result)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_round_e2e.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf tracking")
    ap.add_argument("--no-runtime", action="store_true")
    args = ap.parse_args()
    main(out_path=args.out, include_runtime=not args.no_runtime,
         smoke=args.smoke)
