"""Multi-tenant serving throughput: scan decode, adapters, slot batching.

Three measurements over the smoke transformer (CPU-sized; the same step
functions lower to the production mesh):

  scan-vs-eager   the fused ``lax.scan`` decode against the eager
                  per-token loop at B=4 / new_tokens=64 — the per-token
                  dispatch overhead the scan amortizes into one program.
                  Greedy outputs must match bit-for-bit (decode_parity).
  adapter sweep   tokens/s of the heterogeneous-adapter batch (every row
                  its own ``(basis, R̃)`` via the batched kernel) as the
                  tenant count G sweeps 1→256 at B=8, against (a) the
                  single-adapter table and (b) merged-weight serving
                  (adapter materialized into the dense weights — the
                  per-tenant-copy baseline that cannot batch tenants).
  continuous      SlotServer throughput serving 3x-oversubscribed
                  requests through a fixed slot batch, with per-request
                  greedy parity against straight ``generate``.

Timing hygiene: every clock read is fenced with ``block_until_ready`` on
the stage's outputs (prefill and decode separately — async dispatch would
otherwise charge prefill compute to the decode clock), and the compile
iteration is excluded (best-of-``iters`` steady-state).

Acceptance keys (gated by ``scripts/ci.sh --serve-smoke``):
  decode_parity            scan ≡ eager greedy tokens (exact)
  scan_speedup_b4_n64      eager decode s / scan decode s, must be ≥ 1
  hetero_tput_ratio_g16_b8 G=16 hetero tokens/s / G=1 tokens/s, ≥ 0.8
  continuous_parity        SlotServer ≡ straight generate per request
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core import projector as proj
from repro.core.fed import merge_dense, split_trainable
from repro.launch import adapters as adapters_lib
from repro.launch import serve
from repro.models import model as M

from .common import dump_json, emit

ARCH = "qwen1.5-0.5b"
ADAPTER_SWEEP = (1, 4, 16, 64, 256)
HETERO_GATE_G = 16


def _timed_generate(mode, params, cfg, prompts, new_tokens, cache_len,
                    ids=None, iters=2):
    """Best-of-``iters`` fenced (prefill_s, decode_s) for one serving path;
    the first (compile) iteration is excluded from the clocks."""
    pre = serve._prefill_fn(cfg)
    key = jax.random.PRNGKey(0)
    dec = (serve._scan_decode_fn(cfg, new_tokens - 1, 0.0)
           if mode == "scan" else None)
    step = serve._eager_step_fn(cfg) if mode == "eager" else None
    best_pf = best_dc = float("inf")
    out = None
    for it in range(iters + 1):
        state = M.init_decode_state(cfg, prompts.shape[0], cache_len)
        jax.block_until_ready((params, prompts))
        t0 = time.perf_counter()
        logits, state = pre(params, prompts, state, ids)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if mode == "scan":
            toks = dec(params, tok, state, key, ids)
            jax.block_until_ready(toks)
            out = jnp.concatenate([tok[:, None], toks], axis=1)
        else:
            outl = [tok]
            for _ in range(new_tokens - 1):
                logits, state = step(params, tok, state, ids)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                outl.append(tok)
            jax.block_until_ready(tok)
            out = jnp.stack(outl, axis=1)
        t2 = time.perf_counter()
        if it > 0:
            best_pf = min(best_pf, t1 - t0)
            best_dc = min(best_dc, t2 - t1)
    return out, best_pf, best_dc


def _merge_adapter(params, target_fn, basis, rt, scale=1.0):
    """Materialize one adapter into the dense weights — the per-tenant-copy
    serving baseline (no factored leaves, no batched tenants)."""
    trainable, frozen = split_trainable(params, target_fn)

    def lift(w, b, r):
        w32 = w.astype(jnp.float32)
        if proj.proj_side(w.shape) == proj.RIGHT:
            d = jnp.einsum("...mr,...nr->...mn", jnp.asarray(r),
                           jnp.asarray(b))
        else:
            d = jnp.einsum("...mr,...rn->...mn", jnp.asarray(b),
                           jnp.asarray(r))
        return (scale * w32 + d).astype(w.dtype)

    lifted = jax.tree_util.tree_map(lift, trainable, basis, rt)
    return merge_dense(frozen, lifted)


def bench_scan_vs_eager(cfg, params, *, batch=4, prompt_len=16,
                        new_tokens=64):
    cache = prompt_len + new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    out_e, pf_e, dc_e = _timed_generate("eager", params, cfg, prompts,
                                        new_tokens, cache)
    out_s, pf_s, dc_s = _timed_generate("scan", params, cfg, prompts,
                                        new_tokens, cache)
    parity = bool(jnp.array_equal(out_e, out_s))
    rows = []
    for path, pf, dc in (("eager", pf_e, dc_e), ("scan", pf_s, dc_s)):
        rows.append({"section": "scan_vs_eager", "path": path,
                     "batch": batch, "prompt_len": prompt_len,
                     "new_tokens": new_tokens,
                     "prefill_s": pf, "decode_s": dc,
                     "prefill_tok_s": batch * prompt_len / pf,
                     "decode_tok_s": batch * new_tokens / dc})
    return rows, {"decode_parity": parity,
                  "scan_speedup_b4_n64": dc_e / dc_s}


def bench_adapter_sweep(cfg, params, *, batch=8, prompt_len=16,
                        new_tokens=32, rank=4, sweep=ADAPTER_SWEEP):
    cache = prompt_len + new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(2),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    tf = adapters_lib.serving_target_fn(cfg)
    rng = np.random.default_rng(0)
    g_max = max(sweep)
    store = adapters_lib.AdapterStore(params, tf, g_max, rank)
    factors = []
    for g in range(g_max):
        basis, rt = store.random_factors(rng)
        store.put(g, rt, basis)
        factors.append((basis, rt))

    rows, tok_s = [], {}
    for g in sweep:
        served = store.wrap(params, ids=np.arange(g))
        ids = jnp.arange(batch, dtype=jnp.int32) % g
        _, pf, dc = _timed_generate("scan", served, cfg, prompts,
                                    new_tokens, cache, ids=ids)
        tok_s[g] = batch * new_tokens / dc
        rows.append({"section": "adapter_sweep", "adapters": g,
                     "batch": batch, "new_tokens": new_tokens,
                     "prefill_s": pf, "decode_s": dc,
                     "decode_tok_s": tok_s[g]})

    # merged-weight baseline: one tenant baked into dense weights — what a
    # per-tenant weight copy serves (the whole batch must share it).
    merged = _merge_adapter(params, tf, *factors[0])
    _, pf_m, dc_m = _timed_generate("scan", merged, cfg, prompts,
                                    new_tokens, cache)
    merged_tok_s = batch * new_tokens / dc_m
    rows.append({"section": "adapter_sweep", "adapters": "merged-1",
                 "batch": batch, "new_tokens": new_tokens,
                 "prefill_s": pf_m, "decode_s": dc_m,
                 "decode_tok_s": merged_tok_s})
    gate_g = HETERO_GATE_G if HETERO_GATE_G in tok_s else max(tok_s)
    acc = {"adapter_sweep_tok_s": {str(g): tok_s[g] for g in tok_s},
           "merged_tok_s": merged_tok_s,
           "hetero_gate_adapters": gate_g,
           "hetero_tput_ratio_g16_b8": tok_s[gate_g] / tok_s[min(tok_s)],
           "hetero_vs_merged_g16": tok_s[gate_g] / merged_tok_s}
    return rows, acc


def bench_continuous(cfg, params, *, slots=4, segment=8, prompt_len=12,
                     new_tokens=24, requests=12):
    cache = prompt_len + new_tokens
    rng = np.random.default_rng(3)
    reqs = [serve.Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, prompt_len),
                          max_new=new_tokens)
            for i in range(requests)]
    # warmup: compile prefill/insert/segment on a throwaway server
    serve.SlotServer(params, cfg, slots=slots, cache_len=cache,
                     segment=segment).run([serve.Request(
                         rid=-1, prompt=reqs[0].prompt, max_new=2)])
    server = serve.SlotServer(params, cfg, slots=slots, cache_len=cache,
                              segment=segment)
    out = server.run(reqs)
    stats = out["stats"]
    parity = True
    for r in reqs:
        ref = serve.generate(params, cfg,
                             jnp.asarray(r.prompt, jnp.int32)[None],
                             new_tokens, cache)
        if out["outputs"][r.rid] != ref[0, -new_tokens:].tolist():
            parity = False
    row = {"section": "continuous", "slots": slots, "segment": segment,
           "requests": requests, "new_tokens": new_tokens, **stats}
    acc = {"continuous_parity": parity,
           "continuous_decode_tok_s": stats["decode_tok_s"],
           "continuous_segments": stats["segments"]}
    return [row], acc


def main(out_path="BENCH_serve.json", smoke=False):
    cfg = smoke_variant(get_config(ARCH))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sweep = (1, 4, 16) if smoke else ADAPTER_SWEEP

    rows, acc = [], {}
    r, a = bench_scan_vs_eager(cfg, params)
    rows += r
    acc.update(a)
    r, a = bench_adapter_sweep(cfg, params, sweep=sweep)
    rows += r
    acc.update(a)
    r, a = bench_continuous(cfg, params,
                            requests=8 if smoke else 12)
    rows += r
    acc.update(a)

    result = {"arch": cfg.name, "rows": rows, "acceptance": acc}
    dump_json(out_path, result)
    emit("serve/scan_speedup_b4_n64", 0.0,
         f"x{acc['scan_speedup_b4_n64']:.2f};parity="
         f"{acc['decode_parity']}")
    emit("serve/hetero_ratio_g16_b8", 0.0,
         f"x{acc['hetero_tput_ratio_g16_b8']:.2f};"
         f"vs_merged=x{acc['hetero_vs_merged_g16']:.2f}")
    emit("serve/continuous_decode_tok_s",
         0.0, f"{acc['continuous_decode_tok_s']:.1f};parity="
         f"{acc['continuous_parity']}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="small adapter sweep for CI perf tracking")
    args = ap.parse_args()
    main(out_path=args.out, smoke=args.smoke)
