"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.fed import FedConfig, FedEngine
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    dt = (time.perf_counter() - t0) / iters
    return out, dt


def run_federated_trial(method: str, alpha, *, rounds=8, n_clients=4,
                        local_steps=8, batch=8, seq=16, n_classes=4,
                        examples=512, lr=2e-2, rank=4, seed=0,
                        arch="qwen1.5-0.5b", participation=None,
                        store_dir=None, robust_agg="none", quarantine=False,
                        quarantine_zmax=6.0):
    """One federated fine-tuning run; returns final eval accuracy + curves.

    ``participation`` (a ``core.population.ParticipationConfig``) drives the
    run through ``population.PopulationRunner`` instead of bare engine
    rounds: seeded cohort sampling out of the (possibly larger) virtual
    population, dropout/straggler fault injection, buffered stale
    aggregation, and the per-round drift observatory — the returned dict
    gains ``drift_curve`` (projected-moment divergence) and
    ``stale_err_curve`` (stale-vs-fresh aggregation error). A participation
    config drawing corrupted clients (``corrupt_rate > 0``) turns the run
    adversarial: the runner injects the planned attacks into the compiled
    round, and ``quarantine`` / ``robust_agg`` / ``quarantine_zmax`` select
    the engine's defenses."""
    cfg = smoke_variant(get_config(arch))
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    task = seq_classification(examples, n_classes, seq, cfg.vocab_size,
                              seed=seed)
    population = n_clients
    if participation is not None and participation.population:
        population = participation.population
    batcher = FederatedBatcher(task, population, batch, alpha=alpha,
                               seed=seed)

    def loss(p, b):
        return M.loss_fn(p, cfg, b)

    eng = FedEngine(FedConfig(method=method, rank=rank, lr=lr,
                              local_steps=local_steps, seed=seed,
                              participation=participation,
                              robust_agg=robust_agg, quarantine=quarantine,
                              quarantine_zmax=quarantine_zmax),
                    loss, params, target_fn=galore_target_fn(cfg))
    runner = None
    if participation is not None:
        from repro.core.population import PopulationRunner

        def batches_for(ids, _round):
            b = batcher.round_batches(local_steps,
                                      clients=[int(i) for i in ids])
            return {k: jnp.asarray(v) for k, v in b.items()}

        runner = PopulationRunner(eng, batches_for, cohort=n_clients,
                                  pcfg=participation, store_dir=store_dir)
    eval_b = batcher.eval_batch(256)
    local_curve, val_curve, acc_curve = [], [], []
    drift_curve, stale_err_curve = [], []
    for _ in range(rounds):
        if runner is not None:
            rec = runner.run_round()
            local_curve.append(rec["mean_final_loss"])
            drift_curve.append(rec["moment_divergence"])
            stale_err_curve.append(rec["stale_weight_err"])
        else:
            batches = {k: jnp.asarray(v)
                       for k, v in batcher.round_batches(
                           local_steps,
                           clients=list(range(n_clients))).items()}
            m = eng.run_round(batches)
            local_curve.append(m["mean_final_loss"])
        gp = eng.global_params()
        logits, _ = M.forward(gp, cfg, jnp.asarray(eval_b["tokens"]))
        acc = float((np.asarray(logits[:, -1]).argmax(-1)
                     == eval_b["labels"][:, -1]).mean())
        val_curve.append(float(M.loss_fn(gp, cfg,
                                         {k: jnp.asarray(v)
                                          for k, v in eval_b.items()})))
        acc_curve.append(acc)
    out = {"acc": acc_curve[-1], "acc_curve": acc_curve,
           "local_curve": local_curve, "val_curve": val_curve}
    if runner is not None:
        out["drift_curve"] = drift_curve
        out["stale_err_curve"] = stale_err_curve
        out["history"] = runner.history
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def sanitize_floats(obj):
    """Recursively replace non-finite floats with None. ``json.dump`` emits
    bare ``NaN``/``Infinity`` literals for them (legal Python, illegal
    JSON) — an adversarial bench cell that diverges would otherwise render
    its whole results file unparseable."""
    if isinstance(obj, dict):
        return {k: sanitize_floats(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_floats(v) for v in obj]
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, (np.floating, np.integer, np.bool_)):
        return sanitize_floats(obj.item())
    return obj


def dump_json(path: str, obj):
    """The shared bench results writer: sanitized floats, strict JSON
    (``allow_nan=False`` turns any future escape into a loud error instead
    of an invalid file)."""
    with open(path, "w") as f:
        json.dump(sanitize_floats(obj), f, indent=1, allow_nan=False)
