"""Table 6 / Fig 3ab: the synthetic 'kinetic trap' landscape (Appendix C).

Two SoftMin-combined quadratic basins for W ∈ R^{d×d}:
  * Basin 1 (target): FLAT, centered at c·e2 — orthogonal to the LoRA init
    subspace; robust under aggregation (small Hessian eigenvalues).
  * Basin 2 (trap): SHARP valley at the origin, elongated along e1 (a
    direction inside the initial LoRA subspace).

Full-space SGD, LoRA (B A factors), and GaLore (rank-r gradient projection,
refreshed by SVD) start from randomized inits between the basins; we report
the fraction of trials converging to the flat basin — the paper's numbers
are SGD 91%, GaLore 60%, LoRA 20% (ordering is the claim we validate).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import projector as proj
from .common import emit

D, RANK, TAU = 16, 2, 0.5
C_TARGET = 3.0
H_FLAT, H_SHARP, H_SHALLOW = 0.05, 16.0, 0.02


def _dirs(key):
    e1 = jnp.zeros((D, D)).at[0, 0].set(1.0)       # inside LoRA row space
    e2 = jnp.zeros((D, D)).at[D - 1, D - 1].set(1.0)
    return e1, e2


def make_loss(key):
    e1, e2 = _dirs(key)
    w1 = C_TARGET * e2

    def loss(w):
        # Basin 1: flat isotropic at w1.
        l1 = H_FLAT * jnp.sum((w - w1) ** 2) + 0.0
        # Basin 2: sharp orthogonal / shallow along e1 at origin.
        along = jnp.sum(w * e1)
        rest = w - along * e1
        l2 = H_SHALLOW * along ** 2 + H_SHARP * jnp.sum(rest ** 2) + 0.1
        return -TAU * jnp.log(jnp.exp(-l1 / TAU) + jnp.exp(-l2 / TAU))

    return loss, w1


def run_trial(key, method: str, steps=250, lr=0.05):
    loss, w1 = make_loss(key)
    k1, k2, k3 = jax.random.split(key, 3)
    w_ref = 0.25 * w1                                # closer to the trap
    noise = 0.3 * jax.random.normal(k1, (D, D))

    if method == "sgd":
        w = w_ref + noise
        for _ in range(steps):
            w = w - lr * jax.grad(loss)(w)
        w_final = w
    elif method == "lora":
        w0 = w_ref + noise
        a = 0.3 * jax.random.normal(k2, (RANK, D))
        a = a.at[0, 0].set(1.0)                      # aligned with e1
        b = jnp.zeros((D, RANK))

        def l_ab(ab):
            return loss(w0 + ab[0] @ ab[1])
        ab = (b, a)
        for _ in range(steps):
            g = jax.grad(l_ab)(ab)
            ab = (ab[0] - lr * g[0], ab[1] - lr * g[1])
        w_final = w0 + ab[0] @ ab[1]
    else:  # galore
        w = w_ref + noise
        basis = proj.random_basis(k3[0], D, RANK)
        for t in range(steps):
            g = jax.grad(loss)(w)
            if t % 20 == 0:                          # SVD refresh
                basis = proj.svd_basis(g, RANK, proj.RIGHT)
            gt = proj.project(g, basis, proj.RIGHT)
            w = w - lr * proj.project_back(gt, basis, proj.RIGHT)
        w_final = w
    _, w1 = make_loss(key)
    d_flat = jnp.linalg.norm(w_final - w1)
    d_trap = jnp.linalg.norm(w_final)
    return bool(d_flat < d_trap)


def main(trials=20):
    rows = {}
    for method in ("sgd", "galore", "lora"):
        t0 = time.perf_counter()
        hits = sum(run_trial(jax.random.PRNGKey(100 + i), method)
                   for i in range(trials))
        dt = time.perf_counter() - t0
        frac = hits / trials
        rows[method] = frac
        emit(f"landscape/{method}", dt / trials * 1e6,
             f"flat_basin_frac={frac:.2f}")
    assert rows["sgd"] >= rows["galore"] >= rows["lora"], rows
    with open("bench_landscape.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
