"""Defense-in-depth sweep: adversarial uplinks x defense stacks.

Drives the guarded fused round through ``core.population.PopulationRunner``
with seeded adversary plans (up to ~20% of the cohort corrupted per round)
for the two attack families the paper's robustness appendix injects —
non-finite shards ('nan') and 100x norm attacks ('scale') — against a
ladder of defenses: none, in-round quarantine, and quarantine stacked on a
robust factored aggregator (trimmed mean / geometric median), all in rank-r
factored coordinates (no dense lift anywhere on the defense path).

Acceptance keys (gated by ``scripts/ci.sh --robust-smoke``):
  honest_bit_identity          the all-honest guarded run is EXACTLY the
                               unguarded run (screen no-op, untouched
                               weights — bit-identity by construction,
                               checked end-to-end through the eval curves)
  nan_quarantined              every defended run under the NaN adversary
                               keeps finite train/val curves and a finite
                               global model (the screen catches every
                               poisoned shard in-round)
  attack_degradation_bounded   for each attack, the best defended cell's
                               final val loss stays within
                               ``degradation_bound`` of the honest run,
                               while the undefended cell degrades strictly
                               more (or diverges outright)
"""
from __future__ import annotations

import argparse
import json
import math
import time

from repro.core.population import ParticipationConfig

from .common import emit, run_federated_trial

ATTACKS = ("nan", "scale")
DEFENSES = {
    "none": dict(),
    "quarantine": dict(quarantine=True),
    "quarantine+trimmed": dict(quarantine=True, robust_agg="trimmed_mean"),
    "quarantine+geomedian": dict(quarantine=True, robust_agg="geomedian"),
}
DEFENDED = tuple(k for k in DEFENSES if k != "none")

# The honest bit-identity cell pins zmax high enough that the *verdict*
# passes everyone: heterogeneous smoke cohorts can legitimately disperse
# past the default 6x median norm, and the exactness contract under test is
# the passing screen's no-op, not the verdict policy.
HONEST_ZMAX = 1e6


def _pcfg(seed, corrupt_rate=0.0, modes=("nan",)):
    return ParticipationConfig(corrupt_rate=corrupt_rate,
                               corrupt_modes=modes, attack_scale=100.0,
                               seed=seed + 100)


def _finite(xs):
    return all(math.isfinite(x) for x in xs)


def _cell(attack, defense, *, rounds, n_clients, seed, corrupt_rate):
    r = run_federated_trial(
        "fedgalore", alpha=0.5, rounds=rounds, n_clients=n_clients,
        lr=5e-3, seed=seed,
        participation=_pcfg(seed, corrupt_rate, (attack,)),
        **DEFENSES[defense])
    return {
        "acc": r["acc"],
        "acc_curve": r["acc_curve"],
        "val_curve": r["val_curve"],
        "local_curve": r["local_curve"],
        "corrupted_total": int(sum(h["corrupted"] for h in r["history"])),
        "finite": bool(_finite(r["val_curve"]) and _finite(r["local_curve"])
                       and _finite(r["drift_curve"])),
    }


def main(smoke=False, rounds=None, n_clients=4, seed=0, out=None,
         corrupt_rate=0.2, degradation_bound=1.0):
    rounds = rounds or (4 if smoke else 8)
    t0 = time.perf_counter()

    # Honest reference + the bit-identity cell: same seeds, same runner
    # machinery, guarded program on vs off.
    honest = run_federated_trial("fedgalore", alpha=0.5, rounds=rounds,
                                 n_clients=n_clients, lr=5e-3, seed=seed,
                                 participation=_pcfg(seed))
    honest_guarded = run_federated_trial(
        "fedgalore", alpha=0.5, rounds=rounds, n_clients=n_clients,
        lr=5e-3, seed=seed, participation=_pcfg(seed),
        quarantine=True, quarantine_zmax=HONEST_ZMAX)
    bit_identity = (honest_guarded["val_curve"] == honest["val_curve"]
                    and honest_guarded["acc_curve"] == honest["acc_curve"]
                    and honest_guarded["local_curve"]
                    == honest["local_curve"])

    grid = {}
    n_cells = 2
    for attack in ATTACKS:
        grid[attack] = {}
        for defense in DEFENSES:
            grid[attack][defense] = _cell(
                attack, defense, rounds=rounds, n_clients=n_clients,
                seed=seed, corrupt_rate=corrupt_rate)
            n_cells += 1

    # -- acceptance ---------------------------------------------------------
    honest_val = honest["val_curve"][-1]
    attacks_landed = all(
        c["corrupted_total"] > 0 for a in ATTACKS
        for c in grid[a].values())
    nan_ok = all(grid["nan"][d]["finite"] for d in DEFENDED)

    def _deg(cell):
        if not cell["finite"]:
            return float("inf")
        return cell["val_curve"][-1] - honest_val

    degradation = {a: {d: _deg(grid[a][d]) for d in DEFENSES}
                   for a in ATTACKS}
    bounded = {}
    for a in ATTACKS:
        best = min(degradation[a][d] for d in DEFENDED)
        undefended = degradation[a]["none"]
        bounded[a] = bool(best <= degradation_bound and undefended > best)
    acceptance = {
        "honest_bit_identity": bool(bit_identity),
        "attacks_landed": bool(attacks_landed),
        "nan_quarantined": bool(nan_ok and attacks_landed),
        "attack_degradation_bounded": bool(all(bounded.values())
                                           and attacks_landed),
        "degradation_bound": float(degradation_bound),
        "degradation": {a: {d: (None if math.isinf(v) else float(v))
                            for d, v in degradation[a].items()}
                        for a in ATTACKS},
        "corrupt_rate": float(corrupt_rate),
    }
    dt = time.perf_counter() - t0
    result = {"config": {"rounds": rounds, "n_clients": n_clients,
                         "seed": seed, "smoke": bool(smoke),
                         "attacks": list(ATTACKS),
                         "defenses": list(DEFENSES),
                         "corrupt_rate": corrupt_rate},
              "honest": {"acc": honest["acc"],
                         "val_final": float(honest_val)},
              "grid": grid,
              "acceptance": acceptance,
              "wall_s": dt}
    best_scale = min(degradation["scale"][d] for d in DEFENDED)
    emit("robust", dt / max(n_cells, 1) * 1e6,
         (f"bitid={int(acceptance['honest_bit_identity'])};"
          f"nan_ok={int(acceptance['nan_quarantined'])};"
          f"scale_best_deg={best_scale:.3f};"
          f"bounded={int(acceptance['attack_degradation_bounded'])}"))
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds per cell (CI leg)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_robust.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, rounds=args.rounds, seed=args.seed, out=args.out)
