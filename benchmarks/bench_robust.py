"""Defense-in-depth sweep: adversarial uplinks x defense stacks.

Drives the guarded fused round through ``core.population.PopulationRunner``
with seeded adversary plans (up to ~20% of the cohort corrupted per round)
for the two attack families the paper's robustness appendix injects —
non-finite shards ('nan') and 100x norm attacks ('scale') — against a
ladder of defenses: none, in-round quarantine, and quarantine stacked on a
robust factored aggregator (trimmed mean / geometric median), all in rank-r
factored coordinates (no dense lift anywhere on the defense path).

The runtime leg drives the same seeded adversary schedule through the SPMD
``fedsim.ShardedFederation`` round program via its engine-parity
``run_round(attack=)`` operand — once on the shared seeded basis
(``refresh_mode='random'``) and once with diverged bases
(``refresh_mode='svd'``), where the robust modes re-base every client's
factored stack onto the reference client's basis through the r×r transfer
Grams before the coordinate-wise vote. It also times the quarantined
``run_rounds`` scan pipelined vs sequential at C ∈ {8, 64}.

Acceptance keys (gated by ``scripts/ci.sh --robust-smoke``):
  honest_bit_identity          the all-honest guarded run is EXACTLY the
                               unguarded run (screen no-op, untouched
                               weights — bit-identity by construction,
                               checked end-to-end through the eval curves)
  nan_quarantined              every defended run under the NaN adversary
                               keeps finite train/val curves and a finite
                               global model (the screen catches every
                               poisoned shard in-round)
  attack_degradation_bounded   for each attack, the best defended cell's
                               final val loss stays within
                               ``degradation_bound`` of the honest run,
                               while the undefended cell degrades strictly
                               more (or diverges outright)
  runtime_honest_bit_identity  the all-honest guarded SPMD runtime run is
                               exactly the unguarded runtime run
  hetero_attack_parity         under attack, each defended hetero-basis
                               ('svd') runtime run degrades off its honest
                               same-basis reference at most ``hetero_bound``
                               more than its shared-basis defended twin
                               does — the re-based robust vote does not
                               give back the defense on diverged bases
  quarantine_pipelined_ge_sequential
                               the quarantined run_rounds scan pipelines:
                               pipelined wall-clock ≤ sequential ×
                               ``pipe_noise_tol`` at every timed cohort
"""
from __future__ import annotations

import argparse
import math
import time

import jax

from repro.core.population import ParticipationConfig, corruption_schedule

from .common import dump_json, emit, run_federated_trial

ATTACKS = ("nan", "scale")
DEFENSES = {
    "none": dict(),
    "quarantine": dict(quarantine=True),
    "quarantine+trimmed": dict(quarantine=True, robust_agg="trimmed_mean"),
    "quarantine+geomedian": dict(quarantine=True, robust_agg="geomedian"),
}
DEFENDED = tuple(k for k in DEFENSES if k != "none")

# The honest bit-identity cell pins zmax high enough that the *verdict*
# passes everyone: heterogeneous smoke cohorts can legitimately disperse
# past the default 6x median norm, and the exactness contract under test is
# the passing screen's no-op, not the verdict policy.
HONEST_ZMAX = 1e6


def _pcfg(seed, corrupt_rate=0.0, modes=("nan",)):
    return ParticipationConfig(corrupt_rate=corrupt_rate,
                               corrupt_modes=modes, attack_scale=100.0,
                               seed=seed + 100)


def _finite(xs):
    return all(math.isfinite(x) for x in xs)


def _cell(attack, defense, *, rounds, n_clients, seed, corrupt_rate):
    r = run_federated_trial(
        "fedgalore", alpha=0.5, rounds=rounds, n_clients=n_clients,
        lr=5e-3, seed=seed,
        participation=_pcfg(seed, corrupt_rate, (attack,)),
        **DEFENSES[defense])
    return {
        "acc": r["acc"],
        "acc_curve": r["acc_curve"],
        "val_curve": r["val_curve"],
        "local_curve": r["local_curve"],
        "corrupted_total": int(sum(h["corrupted"] for h in r["history"])),
        "finite": bool(_finite(r["val_curve"]) and _finite(r["local_curve"])
                       and _finite(r["drift_curve"])),
    }


RUNTIME_DEFENSES = {
    "quarantine+trimmed": dict(quarantine=True, robust_agg="trimmed_mean"),
    "quarantine+geomedian": dict(quarantine=True, robust_agg="geomedian"),
}


def _make_runtime(n_clients, refresh_mode, seed, local_steps=2, **knobs):
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=local_steps, seed=seed,
                     refresh_mode=refresh_mode)
    fed = ShardedFederation(cfg, spec, mesh, n_clients, state_sync="ajive",
                            seed=seed, **knobs)
    return cfg, fed


def _runtime_batches(cfg, seed, c, local_steps, k_rounds=None, b=2, seq=8):
    kk = jax.random.PRNGKey(seed)
    lead = ((c, local_steps, b, seq) if k_rounds is None
            else (k_rounds, c, local_steps, b, seq))
    toks = jax.random.randint(kk, lead, 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def _runtime_attack_grid(rounds, n_clients, seed, corrupt_rate,
                         local_steps=2):
    """The SPMD-runtime half of the attack grid: the same seeded 'scale'
    adversary schedule (``population.corruption_schedule``) injected through
    ``ShardedFederation.run_round(attack=)``, once on the shared seeded
    basis and once with diverged per-client bases (``refresh_mode='svd'``,
    where robust 𝒜/𝒮 re-base onto the reference client before the
    coordinate-wise vote)."""
    pcfg = _pcfg(seed, corrupt_rate, ("scale",))
    sched = corruption_schedule(pcfg, n_clients, rounds)
    landed = sum(int((m != 1.0).sum()) for m in sched if m is not None)

    cells = {}
    for mode in ("random", "svd"):
        cells[mode] = {}
        # Honest same-basis reference first: 'svd' and 'random' refresh run
        # genuinely different optimization dynamics, so defense quality is
        # judged per basis mode as degradation OFF this reference, never by
        # comparing svd losses to random losses directly.
        for defense, knobs in [("honest", dict(attack=False))] + [
                (d, k) for d, k in RUNTIME_DEFENSES.items()]:
            attacked = knobs.pop("attack", True)
            cfg, fed = _make_runtime(n_clients, mode, seed,
                                     local_steps=local_steps, **knobs)
            curve = []
            for r in range(rounds):
                res = fed.run_round(
                    _runtime_batches(cfg, seed + r, n_clients, local_steps),
                    attack=sched[r] if attacked else None)
                curve.append(res["mean_final_loss"])
            cell = {"loss_curve": curve, "final_loss": curve[-1],
                    "finite": bool(_finite(curve))}
            if defense != "honest":
                # One-sided: the attack's harm is a WORSENED loss. A
                # defended run landing below the honest reference (the
                # quarantined cohort is a subset — its trajectory may
                # legitimately be better on the junk smoke task) is zero
                # degradation, not negative parity budget.
                ref = cells[mode]["honest"]["final_loss"]
                cell["degradation"] = (
                    max(0.0, curve[-1] - ref) / max(abs(ref), 1e-8)
                    if cell["finite"] else float("inf"))
            cells[mode][defense] = cell
    return cells, landed


def _runtime_honest_identity(rounds, n_clients, seed, local_steps=2):
    """All-honest runtime bit-identity: the guarded program (quarantine on,
    screen forced all-pass, robust machinery compiled in) against the
    unguarded default — identical losses round-for-round, exactly."""
    curves = []
    for knobs in (dict(),
                  dict(quarantine=True, quarantine_zmax=HONEST_ZMAX)):
        cfg, fed = _make_runtime(n_clients, "random", seed,
                                 local_steps=local_steps, **knobs)
        curve = []
        for r in range(rounds):
            res = fed.run_round(
                _runtime_batches(cfg, seed + r, n_clients, local_steps))
            curve.append(res["mean_final_loss"])
        curves.append(curve)
    return curves[0] == curves[1], curves[0]


def _pipeline_timing(clients=(8, 64), k_rounds=4, local_steps=1,
                     pipe_noise_tol=1.25, seed=0, reps=2):
    """Quarantined run_rounds, pipelined vs sequential wall-clock. The
    quarantined scan now pipelines one round deep (the raw round core
    returns post-screen effective weights for the deferred 𝒮) — the gate is
    that it is never slower than the sequential oracle beyond timing
    noise."""
    out = {}
    for c in clients:
        per = {}
        for label, pipe in (("pipelined", True), ("sequential", False)):
            cfg, fed = _make_runtime(
                c, "random", seed, local_steps=local_steps,
                quarantine=True, pipeline_sync=pipe)
            rb = _runtime_batches(cfg, seed, c, local_steps,
                                  k_rounds=k_rounds, b=1)
            for _ in range(2):              # compile + steady-state buffers
                fed.run_rounds(rb)

            def loop(fed=fed, rb=rb):
                t0 = time.perf_counter()
                fed.run_rounds(rb)
                return (time.perf_counter() - t0) / k_rounds
            per[f"{label}_s"] = min(loop() for _ in range(reps))
        per["speedup"] = per["sequential_s"] / per["pipelined_s"]
        per["ok"] = bool(per["pipelined_s"]
                         <= per["sequential_s"] * pipe_noise_tol)
        out[str(c)] = per
        emit(f"robust/quar_pipe_c{c}", per["pipelined_s"] * 1e6,
             f"speedup={per['speedup']:.2f}x")
    return out


def main(smoke=False, rounds=None, n_clients=4, seed=0, out=None,
         corrupt_rate=0.2, degradation_bound=1.0, hetero_bound=0.02,
         pipe_clients=(8, 64), pipe_noise_tol=1.25):
    rounds = rounds or (4 if smoke else 8)
    t0 = time.perf_counter()

    # Honest reference + the bit-identity cell: same seeds, same runner
    # machinery, guarded program on vs off.
    honest = run_federated_trial("fedgalore", alpha=0.5, rounds=rounds,
                                 n_clients=n_clients, lr=5e-3, seed=seed,
                                 participation=_pcfg(seed))
    honest_guarded = run_federated_trial(
        "fedgalore", alpha=0.5, rounds=rounds, n_clients=n_clients,
        lr=5e-3, seed=seed, participation=_pcfg(seed),
        quarantine=True, quarantine_zmax=HONEST_ZMAX)
    bit_identity = (honest_guarded["val_curve"] == honest["val_curve"]
                    and honest_guarded["acc_curve"] == honest["acc_curve"]
                    and honest_guarded["local_curve"]
                    == honest["local_curve"])

    grid = {}
    n_cells = 2
    for attack in ATTACKS:
        grid[attack] = {}
        for defense in DEFENSES:
            grid[attack][defense] = _cell(
                attack, defense, rounds=rounds, n_clients=n_clients,
                seed=seed, corrupt_rate=corrupt_rate)
            n_cells += 1

    # -- acceptance ---------------------------------------------------------
    honest_val = honest["val_curve"][-1]
    attacks_landed = all(
        c["corrupted_total"] > 0 for a in ATTACKS
        for c in grid[a].values())
    nan_ok = all(grid["nan"][d]["finite"] for d in DEFENDED)

    def _deg(cell):
        if not cell["finite"]:
            return float("inf")
        return cell["val_curve"][-1] - honest_val

    degradation = {a: {d: _deg(grid[a][d]) for d in DEFENSES}
                   for a in ATTACKS}
    bounded = {}
    for a in ATTACKS:
        best = min(degradation[a][d] for d in DEFENDED)
        undefended = degradation[a]["none"]
        bounded[a] = bool(best <= degradation_bound and undefended > best)
    # -- SPMD runtime: attack parity, hetero re-basing, pipelined quarantine
    rt_cells, rt_landed = _runtime_attack_grid(
        rounds, n_clients, seed, corrupt_rate)
    rt_identity, rt_honest_curve = _runtime_honest_identity(
        rounds, n_clients, seed)
    # Hetero attack parity: the defense must work as well over diverged
    # per-client bases as over the shared basis — compare each cell's
    # degradation off its own honest same-basis reference (svd and random
    # refresh run different dynamics; raw loss-vs-loss would conflate basis
    # dynamics with defense quality). The svd-basis excess degradation over
    # the shared-basis twin must stay within ``hetero_bound``.
    hetero_rel = {}
    for defense in RUNTIME_DEFENSES:
        shared_c = rt_cells["random"][defense]
        hetero_c = rt_cells["svd"][defense]
        if shared_c["finite"] and hetero_c["finite"]:
            hetero_rel[defense] = max(0.0, hetero_c["degradation"]
                                      - shared_c["degradation"])
        else:
            hetero_rel[defense] = float("inf")
    hetero_parity = bool(rt_landed > 0 and all(
        r <= hetero_bound for r in hetero_rel.values()))
    pipe = _pipeline_timing(clients=pipe_clients,
                            k_rounds=(4 if smoke else 6),
                            pipe_noise_tol=pipe_noise_tol, seed=seed)

    acceptance = {
        "honest_bit_identity": bool(bit_identity),
        "attacks_landed": bool(attacks_landed),
        "nan_quarantined": bool(nan_ok and attacks_landed),
        "attack_degradation_bounded": bool(all(bounded.values())
                                           and attacks_landed),
        "degradation_bound": float(degradation_bound),
        "degradation": {a: {d: (None if math.isinf(v) else float(v))
                            for d, v in degradation[a].items()}
                        for a in ATTACKS},
        "corrupt_rate": float(corrupt_rate),
        "runtime_attacks_landed": bool(rt_landed > 0),
        "runtime_honest_bit_identity": bool(rt_identity),
        "hetero_bound": float(hetero_bound),
        "hetero_parity_rel": {d: (None if math.isinf(v) else float(v))
                              for d, v in hetero_rel.items()},
        "hetero_attack_parity": hetero_parity,
        "pipe_noise_tol": float(pipe_noise_tol),
        "quarantine_pipeline": pipe,
        "quarantine_pipelined_ge_sequential": bool(
            all(p["ok"] for p in pipe.values())),
    }
    dt = time.perf_counter() - t0
    result = {"config": {"rounds": rounds, "n_clients": n_clients,
                         "seed": seed, "smoke": bool(smoke),
                         "attacks": list(ATTACKS),
                         "defenses": list(DEFENSES),
                         "runtime_defenses": list(RUNTIME_DEFENSES),
                         "corrupt_rate": corrupt_rate},
              "honest": {"acc": honest["acc"],
                         "val_final": float(honest_val)},
              "grid": grid,
              "runtime_grid": rt_cells,
              "runtime_honest_curve": rt_honest_curve,
              "acceptance": acceptance,
              "wall_s": dt}
    best_scale = min(degradation["scale"][d] for d in DEFENDED)
    emit("robust", dt / max(n_cells, 1) * 1e6,
         (f"bitid={int(acceptance['honest_bit_identity'])};"
          f"nan_ok={int(acceptance['nan_quarantined'])};"
          f"scale_best_deg={best_scale:.3f};"
          f"bounded={int(acceptance['attack_degradation_bounded'])};"
          f"hetero_parity={int(acceptance['hetero_attack_parity'])};"
          f"quar_pipe={int(acceptance['quarantine_pipelined_ge_sequential'])}"
          ))
    if out:
        dump_json(out, result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds per cell (CI leg)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_robust.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, rounds=args.rounds, seed=args.seed, out=args.out)
