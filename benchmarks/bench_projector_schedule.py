"""Fig 4 / Appendix D: projector schedules — SVD→random (FedGaLore default),
always-SVD, always-random. We measure wall-clock per local step and the loss
reached under a fixed step budget, reporting time-to-loss.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core import galore as gal
from repro.models import model as M
from repro.launch.steps import galore_target_fn
from repro.core.fed import merge_dense, split_trainable
from repro.optim.base import apply_updates
from .common import emit

SCHEDULES = {
    "svd_to_random": dict(adaptive_steps=2, refresh_mode="auto"),
    "always_svd": dict(adaptive_steps=10**9, refresh_mode="svd"),
    "pure_random": dict(adaptive_steps=0, refresh_mode="random"),
}


def run_schedule(name: str, steps=24, refresh_every=4, seed=0):
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    trainable, frozen = split_trainable(params, galore_target_fn(cfg))
    gcfg = gal.GaloreConfig(rank=4, refresh_every=refresh_every,
                            **SCHEDULES[name])
    tx = gal.galore_adamw(gcfg, 3e-3, 0.0, clip_norm=1.0)
    st = tx.init(trainable)

    key = jax.random.PRNGKey(seed + 1)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]

    def loss_of(tr):
        return M.loss_fn(merge_dense(frozen, tr), cfg, batch)

    @jax.jit
    def step(tr, st):
        loss, g = jax.value_and_grad(loss_of)(tr)
        u, st = tx.update(g, st, tr)
        return apply_updates(tr, u), st, loss

    # warmup compile
    t_c = time.perf_counter()
    tr2, st2, l0 = jax.block_until_ready(step(trainable, st))
    compile_s = time.perf_counter() - t_c

    t0 = time.perf_counter()
    tr, sstate, losses = trainable, st, []
    for _ in range(steps):
        tr, sstate, l = step(tr, sstate)
        losses.append(float(l))
    wall = time.perf_counter() - t0
    return {"final_loss": losses[-1], "first_loss": losses[0],
            "wall_s": wall, "per_step_ms": wall / steps * 1e3,
            "compile_s": compile_s,
            "time_to_90pct": wall}


def main():
    rows = {}
    for name in SCHEDULES:
        r = run_schedule(name)
        rows[name] = r
        emit(f"projector_schedule/{name}", r["per_step_ms"] * 1e3,
             f"final_loss={r['final_loss']:.4f};per_step_ms={r['per_step_ms']:.1f}")
    with open("bench_projector_schedule.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
