"""Fault-injection robustness sweep: dropout x staleness x method.

Drives ``core.population.PopulationRunner`` over a grid of participation
faults — per-round client dropout (masked inside the fused round via
weight renormalization + AJIVE joint-basis exclusion) and straggler delays
(contributions landing k rounds late through the bounded staleness buffer)
— for FedGaLore and the FedIT (FedAvg-LoRA) baseline, and records the
drift observatory: projected-moment divergence of the surviving cohort
around the synced v̄, and the stale-vs-fresh aggregation error of each
buffered merge.

Acceptance keys (gated by ``scripts/ci.sh --participation-smoke``):
  masked_round_parity        the no-fault participation run is EXACTLY the
                             plain engine run (full-participation masks
                             short-circuit onto the unmasked program —
                             bit-identity by construction, checked
                             end-to-end through the eval curves)
  stale_drift_bounded        every stale merge's relative aggregation error
                             stays under ``stale_err_bound``
  fedgalore_degradation_ok   fedgalore's worst-cell accuracy drop (vs its
                             own no-fault cell) is no worse than the
                             fedavg-LoRA baseline's, + tolerance
"""
from __future__ import annotations

import argparse
import time

from repro.core.population import ParticipationConfig

from .common import dump_json, emit, run_federated_trial

DROPOUTS = (0.0, 0.25, 0.5)
STALENESS = (0, 1, 4)
METHODS = ("fedgalore", "fedit")        # fedit == FedAvg-LoRA baseline


def _cell(method, dropout, staleness, *, rounds, n_clients, seed,
          straggler_rate):
    pcfg = ParticipationConfig(
        dropout_rate=dropout,
        straggler_rate=(straggler_rate if staleness > 0 else 0.0),
        max_staleness=staleness, staleness_decay=0.5, seed=seed + 100)
    r = run_federated_trial(method, alpha=0.5, rounds=rounds,
                            n_clients=n_clients, lr=5e-3, seed=seed,
                            participation=pcfg)
    return {
        "acc": r["acc"],
        "acc_curve": r["acc_curve"],
        "val_curve": r["val_curve"],
        "drift_curve": r["drift_curve"],
        "stale_err_curve": r["stale_err_curve"],
        "max_drift": max(r["drift_curve"] or [0.0]),
        "max_stale_err": max(r["stale_err_curve"] or [0.0]),
    }


def main(smoke=False, rounds=None, n_clients=4, seed=0, out=None,
         stale_err_bound=0.5, degradation_tol=0.1, straggler_rate=0.5):
    rounds = rounds or (4 if smoke else 8)
    t0 = time.perf_counter()

    # Bit-identity reference: the plain engine run (no participation layer).
    plain = {m: run_federated_trial(m, alpha=0.5, rounds=rounds,
                                    n_clients=n_clients, lr=5e-3, seed=seed)
             for m in METHODS}

    grid = {}
    n_cells = 0
    for method in METHODS:
        grid[method] = {}
        for d in DROPOUTS:
            for s in STALENESS:
                cell = _cell(method, d, s, rounds=rounds,
                             n_clients=n_clients, seed=seed,
                             straggler_rate=straggler_rate)
                grid[method][f"d{d}_s{s}"] = cell
                n_cells += 1

    # -- acceptance ---------------------------------------------------------
    # No-fault cell runs the full-participation masks -> must short-circuit
    # onto the unmasked program: eval curves identical to the plain run.
    parity = all(
        grid[m]["d0.0_s0"]["val_curve"] == plain[m]["val_curve"]
        and grid[m]["d0.0_s0"]["acc_curve"] == plain[m]["acc_curve"]
        for m in METHODS)
    max_stale_err = max(c["max_stale_err"] for m in METHODS
                        for c in grid[m].values())
    degradation = {
        m: max(grid[m]["d0.0_s0"]["acc"] - c["acc"]
               for c in grid[m].values())
        for m in METHODS}
    acceptance = {
        "masked_round_parity": bool(parity),
        "stale_drift_bounded": bool(max_stale_err <= stale_err_bound),
        "max_stale_weight_err": float(max_stale_err),
        "stale_err_bound": float(stale_err_bound),
        "fedgalore_worst_degradation": float(degradation["fedgalore"]),
        "baseline_worst_degradation": float(degradation["fedit"]),
        "degradation_tol": float(degradation_tol),
        "fedgalore_degradation_ok": bool(
            degradation["fedgalore"]
            <= degradation["fedit"] + degradation_tol),
    }
    dt = time.perf_counter() - t0
    result = {"config": {"rounds": rounds, "n_clients": n_clients,
                         "seed": seed, "smoke": bool(smoke),
                         "dropouts": list(DROPOUTS),
                         "staleness": list(STALENESS),
                         "straggler_rate": straggler_rate},
              "grid": grid,
              "plain": {m: {"acc": plain[m]["acc"]} for m in METHODS},
              "acceptance": acceptance,
              "wall_s": dt}
    emit("participation", dt / max(n_cells, 1) * 1e6,
         (f"parity={int(acceptance['masked_round_parity'])};"
          f"stale_err={max_stale_err:.4f};"
          f"galore_deg={degradation['fedgalore']:.3f};"
          f"fedit_deg={degradation['fedit']:.3f}"))
    if out:
        dump_json(out, result)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fewer rounds per cell (CI leg)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_participation.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    main(smoke=args.smoke, rounds=args.rounds, seed=args.seed, out=args.out)
