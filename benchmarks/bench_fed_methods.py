"""Tables 3/4/5 analogue: IID vs non-IID (Dirichlet α=0.5) accuracy for every
federated method on the synthetic sequence-classification task.

The paper's headline claims validated here (relative, not absolute):
  * federated LoRA baselines show a larger IID→non-IID drop Δ than
    FedAvg-Full;
  * FedGaLore keeps Δ small while matching IID accuracy;
  * FedGaLore⁻ (no state sync) degrades more under non-IID than FedGaLore.
"""
from __future__ import annotations

import json
import time

from .common import emit, run_federated_trial

METHODS_ORDER = ["fedavg_full", "fedit", "ffa_lora", "lora_fair", "flora",
                 "fr_lora", "fedgalore_minus", "fedgalore"]

# Per-method learning rates: SGD baselines (FFA-LoRA, LoRA-Fair) need a
# larger step size than the adaptive methods (paper: "we use each baseline's
# original optimizer choice ... otherwise match learning rate").
LR = {"ffa_lora": 0.5, "lora_fair": 0.5}


def main(rounds=8, seeds=(0, 1)):
    rows = {}
    for method in METHODS_ORDER:
        accs = {"iid": [], "noniid": []}
        t0 = time.perf_counter()
        for seed in seeds:
            lr = LR.get(method, 2e-2)
            accs["iid"].append(run_federated_trial(
                method, alpha=None, rounds=rounds, lr=lr, seed=seed)["acc"])
            accs["noniid"].append(run_federated_trial(
                method, alpha=0.5, rounds=rounds, lr=lr, seed=seed)["acc"])
        dt = time.perf_counter() - t0
        iid = sum(accs["iid"]) / len(seeds)
        non = sum(accs["noniid"]) / len(seeds)
        rows[method] = {"iid": iid, "noniid": non, "delta": iid - non}
        emit(f"fed_methods/{method}",
             dt / (2 * len(seeds) * rounds) * 1e6,
             f"iid={iid:.3f};noniid={non:.3f};delta={iid - non:+.3f}")
    with open("bench_fed_methods.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
