"""Table 7: AJIVE server-side latency vs (views × n) on dense n×n inputs.

The paper reports ≈93 ms on CPU for views=5, n=1024 — we measure our jnp
implementation on this container's CPU and also report estimated FLOPs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.ajive import ajive_sync
from .common import emit, timed


def est_flops(k, n, r=8):
    # phase1: k economy SVDs O(n^2 r) + phase2 joint SVD O(n (k r)^2)
    # + phase3 projections O(k n^2 r)
    return k * 2 * n * n * r + n * (k * r) ** 2 + k * 2 * n * n * r


def main(views=(1, 2, 5, 10), sizes=(512, 768, 1024), rank=8, seed=0):
    rows = []
    for k in views:
        for n in sizes:
            key = jax.random.PRNGKey(seed)
            data = jnp.abs(jax.random.normal(key, (max(k, 2), n, n)))
            data = data[:k] if k >= 2 else data[:2]   # ajive needs >= 2 views
            kk = data.shape[0]
            fn = jax.jit(lambda v: ajive_sync(v, rank=rank))
            _, dt = timed(fn, data, warmup=1, iters=2)
            rows.append({"views": k, "n": n, "sec": dt,
                         "est_flops": est_flops(kk, n, rank)})
            emit(f"ajive_latency/v{k}_n{n}", dt * 1e6,
                 f"flops={est_flops(kk, n, rank):.3e}")
    with open("bench_ajive_latency.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
