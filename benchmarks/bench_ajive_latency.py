"""Table 7: AJIVE server-side latency vs (views × n).

Two input regimes per (views, n) cell:

  dense     — n×n lifted views through the dense ``ajive_sync`` pipeline
              (the paper's Table-7 setting; ≈93 ms on CPU for views=5,
              n=1024 in the paper's measurement).
  factored  — the production uplink: projected ``(C, n, r)`` moments through
              ``ajive_sync_factored`` (r×r Grams + (C·r) score Gram), the
              path every default 𝒮 configuration actually executes.

Both land in the JSON so the dense-vs-factored gap is tracked alongside the
paper's numbers; estimated FLOPs accompany each regime.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.ajive import ajive_sync, ajive_sync_factored
from .common import emit, timed


def est_flops(k, n, r=8):
    # phase1: k economy SVDs O(n^2 r) + phase2 joint SVD O(n (k r)^2)
    # + phase3 projections O(k n^2 r)
    return k * 2 * n * n * r + n * (k * r) ** 2 + k * 2 * n * n * r


def est_flops_factored(k, n, r=8):
    # phase1: k r×r Grams O(n r^2) + phase2 (k r)² score Gram O(n (k r)^2)
    # + phase3 two skinny GEMMs O(k n r^2) — never O(n^2)
    return k * 2 * n * r * r + 2 * n * (k * r) ** 2 + k * 4 * n * r * r


def main(views=(1, 2, 5, 10), sizes=(512, 768, 1024), rank=8, seed=0):
    rows = []
    for k in views:
        for n in sizes:
            key = jax.random.PRNGKey(seed)
            data = jnp.abs(jax.random.normal(key, (max(k, 2), n, n)))
            data = data[:k] if k >= 2 else data[:2]   # ajive needs >= 2 views
            kk = data.shape[0]
            fn = jax.jit(lambda v: ajive_sync(v, rank=rank))
            _, dt = timed(fn, data, warmup=1, iters=2)

            # factored path on the projected (C, n, r) uplink payload
            vproj = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                              (kk, n, rank)))
            ffn = jax.jit(lambda v: ajive_sync_factored(v, rank=rank))
            _, dtf = timed(ffn, vproj, warmup=1, iters=2)

            rows.append({"views": k, "n": n, "sec": dt,
                         "est_flops": est_flops(kk, n, rank),
                         "factored_sec": dtf,
                         "factored_est_flops": est_flops_factored(kk, n,
                                                                  rank),
                         "factored_speedup": dt / dtf})
            emit(f"ajive_latency/v{k}_n{n}", dt * 1e6,
                 f"flops={est_flops(kk, n, rank):.3e}")
            emit(f"ajive_latency/v{k}_n{n}_factored", dtf * 1e6,
                 f"speedup={dt / dtf:.0f}x")
    with open("bench_ajive_latency.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
