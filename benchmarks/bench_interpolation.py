"""Fig 3c analogue: linear-interpolation loss barrier between client models.

Two clients train from a shared init on disjoint (non-IID) halves of the
task with (a) full fine-tuning, (b) LoRA, (c) GaLore. We evaluate the global
loss along θ(t) = (1-t)·θ_A + t·θ_B and report two connectivity metrics:

    barrier  = max_t L(θ(t)) − max(L(θ_A), L(θ_B))        (≥ 0)
    midpoint = L(θ(0.5)) − ½(L(θ_A) + L(θ_B))             (sign-sensitive)

At smoke scale the hard barrier is often exactly 0 (both endpoints stay in
one convex region after ≤60 local steps), so the sign-sensitive midpoint
excess is the informative statistic. Paper claim: FFT and GaLore interpolate
better than LoRA.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.fed import FedConfig, FedEngine
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M
from .common import emit

METHOD_OF = {"fft": "fedavg_full", "galore": "fedgalore_minus",
             "lora": "fedit"}


def client_models(kind: str, rounds=10, seed=0):
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    task = seq_classification(512, 4, 16, cfg.vocab_size, seed=seed)
    batcher = FederatedBatcher(task, 2, 8, alpha=0.05, seed=seed)

    def loss(p, b):
        return M.loss_fn(p, cfg, b)

    eng = FedEngine(FedConfig(method=METHOD_OF[kind], rank=4, lr=2e-2,
                              local_steps=6, seed=seed),
                    loss, params, target_fn=galore_target_fn(cfg))
    # one broadcast, then LOCAL-ONLY training (no aggregation): capture the
    # two client endpoints by running a round and reading stacked trainables.
    batches = {k: jnp.asarray(v) for k, v in
               batcher.round_batches(6 * rounds).items()}
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (2,) + x.shape), eng.global_trainable)
    opt = eng._init_client_opt_states(2)
    out_tr, _, _ = eng._local_train(stacked, opt, batches, eng.frozen)

    def client_params(i):
        tr = jax.tree_util.tree_map(lambda x: x[i], out_tr)
        if eng.spec.trainable in ("dense", "galore"):
            from repro.core.fed import merge_dense
            return merge_dense(eng.frozen, tr)
        from repro.core.fed import merge_lora
        return merge_lora(eng.frozen, tr, eng.cfg.lora_scale)

    eval_b = {k: jnp.asarray(v) for k, v in batcher.eval_batch(256).items()}
    return cfg, client_params(0), client_params(1), eval_b


def barrier(kind: str, n_pts=9, seed=0):
    cfg, pa, pb, eval_b = client_models(kind, seed=seed)

    def loss_at(t):
        p = jax.tree_util.tree_map(
            lambda a, b: (1 - t) * a.astype(jnp.float32)
            + t * b.astype(jnp.float32), pa, pb)
        return float(M.loss_fn(p, cfg, eval_b))

    ts = np.linspace(0, 1, n_pts)
    path = [loss_at(float(t)) for t in ts]
    hard = max(path) - max(path[0], path[-1])
    mid = path[n_pts // 2] - 0.5 * (path[0] + path[-1])
    return hard, mid, path


def main(seeds=(0, 1)):
    rows = {}
    for kind in ("fft", "galore", "lora"):
        t0 = time.perf_counter()
        res = [barrier(kind, seed=s) for s in seeds]
        dt = time.perf_counter() - t0
        rows[kind] = {"barrier": float(np.mean([r[0] for r in res])),
                      "midpoint_excess": float(np.mean([r[1] for r in res]))}
        emit(f"interpolation/{kind}", dt / len(seeds) * 1e6,
             f"barrier={rows[kind]['barrier']:.4f};"
             f"midpoint={rows[kind]['midpoint_excess']:+.4f}")
    with open("bench_interpolation.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
