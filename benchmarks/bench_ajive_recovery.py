"""Fig 5 / Appendix F: AJIVE recovers the global second moment under
structured drift where naive averaging is biased.

V* = (G*)⊙² with rank-5 G*; clients observe G_k = G* + L_k (rank-2 drift) +
noise and compute V_k = G_k⊙². We compare ‖V_est − V*‖_F for naive averaging,
average+SVD, AJIVE rank-5, and AJIVE rank-15 (the r(r+1)/2 rank-expansion
point) as the number of clients grows.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.ajive import ajive_sync
from .common import emit

N, M, R = 48, 48, 5


def make_problem(key, k_clients, drift=1.0, noise=0.1):
    k1, k2, k3 = jax.random.split(key, 3)
    g_star = (jax.random.normal(k1, (N, R)) @ jax.random.normal(k2, (R, M))
              / jnp.sqrt(R))
    v_star = g_star ** 2
    views = []
    for i in range(k_clients):
        ki = jax.random.fold_in(k3, i)
        a, b, c = jax.random.split(ki, 3)
        drift_m = (jax.random.normal(a, (N, 2)) @ jax.random.normal(b, (2, M))
                   * drift / jnp.sqrt(2))
        g_k = g_star + drift_m + noise * jax.random.normal(c, (N, M))
        views.append(g_k ** 2)
    return jnp.stack(views), v_star


def estimators(views):
    k = views.shape[0]
    naive = jnp.mean(views, axis=0)
    u, s, vt = jnp.linalg.svd(naive, full_matrices=False)
    avg_svd15 = (u[:, :15] * s[:15][None]) @ vt[:15]
    out = {"naive": naive, "avg_svd_r15": avg_svd15}
    if k >= 2:
        out["ajive_r5"] = ajive_sync(views, rank=5)
        out["ajive_r15"] = ajive_sync(views, rank=15)
    return out


def main(client_counts=(2, 4, 8, 16), seed=0):
    rows = {}
    t0 = time.perf_counter()
    for k in client_counts:
        views, v_star = make_problem(jax.random.PRNGKey(seed), k)
        errs = {name: float(jnp.linalg.norm(est - v_star)
                            / jnp.linalg.norm(v_star))
                for name, est in estimators(views).items()}
        rows[str(k)] = errs
    dt = time.perf_counter() - t0
    last = rows[str(client_counts[-1])]
    emit("ajive_recovery", dt / len(client_counts) * 1e6,
         (f"K={client_counts[-1]};naive={last['naive']:.3f};"
          f"ajive_r15={last['ajive_r15']:.3f}"))
    # Paper claims at the largest K: AJIVE r15 < post-hoc SVD < naive.
    assert last["ajive_r15"] < last["naive"], rows
    with open("bench_ajive_recovery.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
